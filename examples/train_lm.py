"""End-to-end LM training driver (deliverable b): trains a ~100M-param
reduced-family transformer on synthetic tokens and reports the loss curve
— exercising the same model/optimizer/data/ckpt stack the production
launcher uses.  (The paper-native end-to-end driver is examples/
quickstart.py — full-batch GNN training for 60 epochs; this one covers the
architecture-zoo side.)

Default arch is musicgen-large (vocab 2048) so the LM head doesn't
dominate CPU time; pass --steps 300 for a full curve.

    PYTHONPATH=src python examples/train_lm.py --steps 40
"""
import argparse
import dataclasses
import json
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="musicgen-large")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.data import synthetic_token_batches
    from repro.models.transformer import init_model, train_step_fn, param_count
    from repro.optim import adamw

    # ~100M-class variant: reduced family config widened to 10 layers/1024d
    cfg = dataclasses.replace(get_reduced(args.arch), num_layers=10,
                              d_model=1024, n_heads=16, n_kv_heads=8,
                              d_ff=2816, dtype="float32")
    n = param_count(cfg)
    print(f"training {cfg.name} variant: {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq_len}")

    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw(args.lr)
    opt_state = opt.init(params)
    step = jax.jit(train_step_fn(cfg, opt))
    gen = synthetic_token_batches(cfg.vocab_size, args.seq_len, args.batch,
                                  seed=0)
    losses = []
    t0 = time.perf_counter()
    for i, hb in zip(range(args.steps), gen):
        batch = {"tokens": jnp.asarray(hb["tokens"]),
                 "labels": jnp.asarray(hb["labels"])}
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % 25 == 0:
            print(f"  step {i+1:4d}  loss {losses[-1]:.4f}")
    wall = time.perf_counter() - t0
    out = {"arch": cfg.name, "params_m": round(n / 1e6, 1),
           "loss_first": losses[0], "loss_last": losses[-1],
           "loss_decreased": losses[-1] < losses[0],
           "tokens_per_s": round(args.steps * args.batch * args.seq_len
                                 / wall, 1)}
    print(json.dumps(out, indent=1))
    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        # same payload as repro.launch.train, so `train lm --resume` can
        # continue from this checkpoint
        save_checkpoint(args.ckpt_dir, args.steps,
                        {"params": params, "opt_state": opt_state})
        print("checkpoint saved to", args.ckpt_dir)


if __name__ == "__main__":
    main()
