"""Heterogeneous-device demo: RAPA vs uniform partitioning (paper Fig. 21).

Sweeps the paper's Table-4 device groups (x2 homogeneous ... x8 strongly
heterogeneous), shows the per-device cost model before/after RAPA, and
trains briefly on the most heterogeneous group to show accuracy holds.

    PYTHONPATH=src python examples/heterogeneous_rapa.py
"""
import numpy as np

from repro.core import (PAPER_GROUPS, RapaConfig, StalenessController,
                        build_cache_plan, cal_capacity, do_partition,
                        make_group, partition_lambdas)
from repro.data import make_task
from repro.dist import (build_exchange_plan, make_sim_runtime,
                        stack_partitions, train_capgnn)
from repro.graph import build_partition, metis_partition
from repro.models.gnn import GNNConfig
from repro.optim import adam


def main():
    task = make_task("flickr", scale=0.05, feat_dim=64, seed=0)
    cfg_r = RapaConfig(feat_dim=64)
    # Eq. 15 objective: the MAX per-device cost is the step-time bound.
    print(f"{'group':5s} {'het':>5s} {'uniform max-cost':>17s} {'rapa max-cost':>14s}")
    for grp in ("x2", "x4", "x6", "x8"):
        profiles = make_group(PAPER_GROUPS[grp])
        p = len(profiles)
        ps = build_partition(task.graph, metis_partition(task.graph, p, seed=0),
                             hops=1)
        lam0 = partition_lambdas(ps, profiles, cfg_r)
        res = do_partition(ps, profiles, cfg_r)
        lam1 = res.lambda_final
        het = max(pr.mm for pr in profiles) / min(pr.mm for pr in profiles)
        print(f"{grp:5s} {het:5.1f} {lam0.max():17.3e} {np.max(lam1):14.3e}")

    # train on the x8 group with the RAPA-balanced partitions
    profiles = make_group(PAPER_GROUPS["x8"])
    ps = build_partition(task.graph,
                         metis_partition(task.graph, 8, seed=0), hops=1)
    ps = do_partition(ps, profiles, cfg_r).partition_set
    gcfg = GNNConfig(model="sage", in_dim=64, hidden_dim=128,
                     out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, gcfg.feat_dims, profiles)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    rt = make_sim_runtime(gcfg, sp, xplan, opt)
    params, rep = train_capgnn(gcfg, rt, xplan, 8, opt, epochs=40,
                               controller=StalenessController(refresh_every=4))
    _, acc = rt.evaluate(params, "test")
    print(f"\nx8 GraphSAGE: loss {rep.losses[-1]:.4f}, test acc {acc:.3f}, "
          f"comm saved {rep.comm_reduction:.1%}")


if __name__ == "__main__":
    main()
