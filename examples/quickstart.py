"""Quickstart: CaPGNN full-batch GNN training on a partitioned graph.

Builds a scaled Flickr-like graph, partitions it METIS-style, plans the
JACA two-level cache, balances partitions with RAPA against a heterogeneous
device group, and trains a 3-layer GCN with the staleness-scheduled step
pair — printing the exact communication bytes saved vs vanilla.

    PYTHONPATH=src python examples/quickstart.py
"""
import json

import jax

from repro.core import (PAPER_GROUPS, RapaConfig, StalenessController,
                        build_cache_plan, cal_capacity, do_partition,
                        halo_stats, make_group)
from repro.data import make_task
from repro.dist import (build_exchange_plan, make_sim_runtime,
                        stack_partitions, train_capgnn)
from repro.graph import build_partition, metis_partition
from repro.models.gnn import GNNConfig
from repro.optim import adam


def main():
    # 1. Data + graph partitioning ---------------------------------------
    task = make_task("flickr", scale=0.05, feat_dim=64, seed=0)
    parts = 4
    assign = metis_partition(task.graph, parts, seed=0)
    ps = build_partition(task.graph, assign, hops=1)
    print("halo stats:", json.dumps(halo_stats(ps).as_dict(), indent=1))

    # 2. RAPA: balance partitions against a heterogeneous device group ---
    profiles = make_group(PAPER_GROUPS["x4"])   # 2x RTX3090 + 2x A40
    rapa = do_partition(ps, profiles, RapaConfig(feat_dim=64))
    ps = rapa.partition_set
    print(f"RAPA: removed {rapa.removed_per_part} halo replicas/part, "
          f"cost rel-std {rapa.history[0]['std']/max(rapa.history[0]['lambda'].mean(),1e-9):.3f}"
          f" -> {rapa.history[-1]['std']/max(rapa.history[-1]['lambda'].mean(),1e-9):.3f}")

    # 3. JACA: adaptive capacity + two-level cache plan ------------------
    cfg = GNNConfig(model="gcn", in_dim=64, hidden_dim=128,
                    out_dim=task.num_classes, num_layers=3)
    cap = cal_capacity(ps, cfg.feat_dims, profiles)
    plan = build_cache_plan(ps, cap, refresh_every=4)
    xplan = build_exchange_plan(ps, plan)

    # 4. Train with the staleness-scheduled step pair --------------------
    sp = stack_partitions(ps, task)
    opt = adam(0.01)
    runtime = make_sim_runtime(cfg, sp, xplan, opt)
    ctl = StalenessController(refresh_every=4)
    params, report = train_capgnn(cfg, runtime, xplan, parts, opt,
                                  epochs=60, controller=ctl, pipeline=True)
    _, test_acc = runtime.evaluate(params, "test")

    print(f"final loss {report.losses[-1]:.4f}  test acc {test_acc:.3f}")
    print(f"comm {report.comm_bytes/2**20:.1f} MiB "
          f"(vanilla {report.comm_bytes_vanilla/2**20:.1f} MiB, "
          f"saved {report.comm_reduction:.1%}) over "
          f"{report.refresh_steps} refresh + {report.cached_steps} cached steps")


if __name__ == "__main__":
    main()
